"""Fleet mode: many concurrent tuned transfers sharing one link.

Fits the offline knowledge base once, then runs an 8-tenant fleet over the
XSEDE testbed twice — naive all-at-once admission vs the contention-aware
admission controller — and prints the roll-up each produces.  Both runs go
through the unified ``run_fleet`` facade; flip ``engine="vectorized"`` in
the ``EngineConfig`` to use the event-loop engine that scales to 1e5+
sessions (bit-identical results at this size).

A third run wires admission through the streaming ``KnowledgeService``:
completed sessions fold back into the knowledge base as mini-batch
centroid updates, full refits fire only on the drift/staleness bounds,
and the service's counters report what the stream did.

    PYTHONPATH=src python examples/fleet.py
"""

from repro.core import (
    EngineConfig,
    FleetRequest,
    KnowledgeService,
    ServiceConfig,
    TransferTuner,
    TunerConfig,
    run_fleet,
)
from repro.netsim import generate_history, make_dataset, make_testbed

N = 8

env = make_testbed("xsede", seed=3)
hist = generate_history(env, days=6, transfers_per_day=150, seed=0)
db = TransferTuner(TunerConfig(seed=0)).fit(hist).db

requests = [
    FleetRequest(
        dataset=make_dataset(["small", "medium", "large"][i % 3], 30 + i),
        env_seed=500 + i,
        start_clock_s=4 * 3600.0,
        constant_load=0.15,
    )
    for i in range(N)
]

print(f"=== {N}-tenant fleet on xsede (shared 10 Gbps link) ===")
for label, config in [
    ("naive (admit all at once)", EngineConfig(max_concurrent=N)),
    ("contention-aware admission", EngineConfig()),
]:
    fleet = run_fleet(db, list(requests), config)
    print(
        f"  {label:28s} cap={fleet.admitted_concurrency} "
        f"goodput={fleet.goodput_mbps:,.0f} Mbps "
        f"makespan={fleet.makespan_s:,.0f} s"
    )
    print(
        f"  {'':28s} samples p50/p99={fleet.samples_p50:.0f}/"
        f"{fleet.samples_p99:.0f} "
        f"accuracy vs single-tenant opt={fleet.accuracy_vs_single:.1f}% "
        f"re-probes={fleet.reprobe_grants} "
        f"(+{fleet.reprobe_denials} storm-damped)"
    )

# Streaming knowledge: a fresh DB (the service mutates it in place) served
# through the KnowledgeService facade — admission snapshots, per-session
# probe budgets, and completed-session ingest all resolve through it.
db2 = TransferTuner(TunerConfig(seed=0)).fit(hist).db
service = KnowledgeService(
    db2, ServiceConfig(max_staleness_s=600.0, drift_threshold=0.25)
)
fleet = run_fleet(db2, list(requests), EngineConfig(knowledge=service))
stats = service.stats()
print(
    f"  {'streaming knowledge service':28s} cap={fleet.admitted_concurrency} "
    f"goodput={fleet.goodput_mbps:,.0f} Mbps "
    f"makespan={fleet.makespan_s:,.0f} s"
)
print(
    f"  {'':28s} minibatch updates={stats.minibatch_updates} "
    f"refits={stats.refits} entries folded={stats.entries_folded}"
)
